"""Telemetry spine (repro.obs) tests.

The contract under test: recording NEVER reads a device value (futures
materialise only at drain, after the owner's block), the trainer's
history keeps its exact shape while being backed by the bus, the human
log lines are byte-identical to the prints they replaced, telemetry is
a bitwise no-op on the trajectory, the drift monitor warns exactly once
per band excursion, and the declared history schema rejects undeclared
keys so new metrics can't rot silently.
"""
import io
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fopo import FOPOConfig
from repro.data import SyntheticConfig, generate_sessions
from repro.health import FaultPlan, HealthConfig
from repro.health.guard import ESS_COLLAPSE, verdict_record
from repro.obs import (
    HISTORY_SCHEMA,
    DriftConfig,
    DriftMonitor,
    HumanLogSink,
    JSONLSink,
    MetricsBus,
    ObsConfig,
    ObsRun,
    RingSink,
    Tracer,
    span,
    tracing,
    validate_history,
)
from repro.obs import trace as trace_mod
from repro.obs.report import percentile, render_run
from repro.obs.schema import empty_history, history_from_records
from repro.obs.sinks import format_rollback_line, format_train_line
from repro.train import FOPOTrainer, TrainerConfig


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ds():
    full = generate_sessions(SyntheticConfig(
        num_items=300, num_users=200, embed_dim=16, session_len=8, seed=0
    ))
    train, _ = full.split(0.85, seed=0)
    return train


def _trainer(ds, *, obs=None, health=None, fault=None, steps=8, seed=0):
    fopo = FOPOConfig(
        num_items=300, num_samples=32, top_k=16, epsilon=0.8,
        retriever="streaming",
    )
    cfg = TrainerConfig(
        estimator="fopo", fopo=fopo, batch_size=16, learning_rate=1e-3,
        num_steps=steps, checkpoint_every=0, seed=seed, health=health,
        obs=obs,
    )
    return FOPOTrainer(cfg, ds, fault_plan=fault)


# ---------------------------------------------------------------------------
# the metrics bus
# ---------------------------------------------------------------------------

def test_bus_records_kinds_and_totals():
    ring = RingSink()
    bus = MetricsBus([ring])
    bus.counter("c", 2.0)
    bus.counter("c", 3.0, step=4)
    bus.gauge("g", 1.5, step=1, route="x")
    bus.timing("t", 0.25)
    bus.event("e", {"a": 1})
    # nothing reaches a sink before drain
    assert bus.pending == 5 and len(ring.records) == 0
    assert bus.drain() == 5 and bus.pending == 0
    assert [r["kind"] for r in ring.records] == [
        "counter", "counter", "gauge", "timing", "event"
    ]
    assert bus.total("c") == 5.0 and bus.total("never") == 0.0
    g = ring.records[2]
    assert g["step"] == 1 and g["labels"] == {"route": "x"}


class _Probe:
    """float() tripwire: materialising before the owner's block (i.e. at
    record time) is exactly the host sync the bus must never add."""

    def __init__(self):
        self.allowed = False

    def __float__(self):
        if not self.allowed:
            raise AssertionError("device value read at record time")
        return 7.0


def test_bus_defers_value_reads_to_drain():
    ring = RingSink()
    bus = MetricsBus([ring])
    probe = _Probe()
    bus.gauge("loss", probe, step=0)  # must not call float() here
    assert bus.pending == 1
    probe.allowed = True  # "block_until_ready happened"
    bus.drain()
    assert ring.records[0]["value"] == 7.0


def test_bus_recording_keeps_single_trace():
    """Recording in-flight device scalars every step neither retraces
    nor blocks the jitted step (the test_refresh cache-size trick)."""
    bus = MetricsBus([RingSink()])

    @jax.jit
    def step(x):
        return x * 2.0, jnp.sum(x)

    x = jnp.ones((8,))
    for i in range(5):
        x, s = step(x)
        bus.gauge("s", s, step=i)  # the future, recorded in flight
    jax.block_until_ready(x)
    assert step._cache_size() == 1
    bus.drain()


def test_ring_capacity_bounds():
    ring = RingSink(capacity=3)
    bus = MetricsBus([ring])
    for i in range(10):
        bus.gauge("g", float(i))
    bus.drain()
    assert [r["value"] for r in ring.records] == [7.0, 8.0, 9.0]


def test_jsonl_sink_roundtrip_and_append(tmp_path):
    path = str(tmp_path / "m.jsonl")
    sink = JSONLSink(path)
    sink.emit({"t": 0, "kind": "event", "name": "e", "value": {"x": 1}})
    sink.emit({"t": 0, "kind": "event", "name": "bad", "value": object()})
    sink.close()
    lines = [json.loads(line) for line in open(path)]
    assert lines[0]["value"] == {"x": 1}
    assert isinstance(lines[1]["value"], str)  # repr fallback, not a crash
    # append mode: a second train() call on the same run_dir extends
    sink2 = JSONLSink(path)
    sink2.emit({"t": 1, "kind": "gauge", "name": "g", "value": 2.0})
    sink2.close()
    assert len(open(path).readlines()) == 3


def test_jsonl_sink_retries_transient_write_failures(tmp_path):
    # two injected failures, then success: the record must land after
    # reopen+retry — a disk hiccup must not kill a serving process
    path = str(tmp_path / "m.jsonl")
    sink = JSONLSink(path, retries=3, backoff=0.0)
    fails = [2]
    real_write = sink._f.write

    class Flaky:
        def write(self, s):
            if fails[0] > 0:
                fails[0] -= 1
                raise OSError("injected transient IO failure")
            return real_write(s)

        def close(self):
            pass

        def flush(self):
            pass

    sink._f = Flaky()
    sink.emit({"t": 0, "kind": "gauge", "name": "g", "value": 1.0})
    sink.close()
    lines = [json.loads(line) for line in open(path)]
    assert lines and lines[-1]["value"] == 1.0


def test_jsonl_sink_disarms_after_exhausted_retries(tmp_path, capsys):
    # persistent failure: the sink disarms itself (emits become no-ops)
    # instead of raising into the serving loop
    path = str(tmp_path / "m.jsonl")
    sink = JSONLSink(path, retries=2, backoff=0.0)

    class Dead:
        def write(self, s):
            raise OSError("disk on fire")

        def close(self):
            raise OSError("still on fire")

        def flush(self):
            pass

    sink._f = Dead()
    real_reopen = sink._reopen
    sink._reopen = lambda: None  # reopen keeps handing back the dead handle
    sink.emit({"t": 0, "kind": "gauge", "name": "g", "value": 1.0})
    assert sink._f is None
    assert "disarmed" in capsys.readouterr().err
    sink.emit({"t": 0, "kind": "gauge", "name": "g", "value": 2.0})  # no-op
    sink.close()  # and close stays safe
    del real_reopen


def test_jsonl_sink_backoff_sleep_is_bounded(tmp_path, monkeypatch, capsys):
    # the sink sits on the serving drain path: a persistently failing
    # disk must not stall a batch interval — total ladder sleep is
    # capped at max_sleep_s, then the sink disarms
    import repro.obs.sinks as sinks_mod

    slept = []
    monkeypatch.setattr(sinks_mod.time, "sleep", lambda s: slept.append(s))
    path = str(tmp_path / "m.jsonl")
    sink = JSONLSink(path, retries=8, backoff=0.05, max_sleep_s=0.08)

    class Dead:
        def write(self, s):
            raise OSError("disk on fire")

        def close(self):
            pass

        def flush(self):
            pass

    sink._f = Dead()
    sink._reopen = lambda: None
    sink.emit({"t": 0, "kind": "gauge", "name": "g", "value": 1.0})
    assert sink._f is None  # still disarms
    assert sum(slept) <= 0.08 + 1e-9
    capsys.readouterr()


def test_human_log_sink_prints_only_log_records():
    out = io.StringIO()
    sink = HumanLogSink(stream=out)
    sink.emit({"t": 0.0, "kind": "gauge", "name": "loss", "value": 1.0})
    sink.emit({"t": 0.0, "kind": "event", "name": "log", "value": "hello"})
    assert out.getvalue() == "hello\n"  # verbatim, no stamp by default
    stamped = io.StringIO()
    HumanLogSink(stream=stamped, timestamps=True).emit(
        {"t": 0.0, "kind": "event", "name": "log", "value": "hello"}
    )
    assert stamped.getvalue().endswith(" hello\n")
    assert len(stamped.getvalue()) > len("hello\n")


def test_format_helpers_match_legacy_print_strings():
    aux = {"ess": 25.44, "rbar": 0.0143, "max_wbar": 0.0621}
    step, loss = 40, -0.0123456
    legacy = f"step {step}: loss={loss:+.5f}"
    legacy += (
        f" ess={aux['ess']:.1f} rbar={aux['rbar']:+.4f}"
        f" max_wbar={aux['max_wbar']:.3f}"
    )
    assert format_train_line(step, loss, aux) == legacy
    assert (
        format_train_line(step, loss, aux, ("ess_collapse",), True)
        == legacy + " health=ess_collapse [degraded:exact]"
    )
    assert format_train_line(3, 0.5) == "step 3: loss=+0.50000"
    assert format_rollback_line(7, 4, 2) == "step 7: ROLLBACK to 4 (restart #2)"


# ---------------------------------------------------------------------------
# phase tracing
# ---------------------------------------------------------------------------

def test_tracer_spans_nest_and_write(tmp_path):
    tr = Tracer()
    with tracing(tr):
        with span("outer", step=1):
            with span("inner"):
                pass
    # complete events append at close: inner first, outer envelops it
    assert [e["name"] for e in tr.events] == ["inner", "outer"]
    inner, outer = tr.events
    assert outer["dur"] >= inner["dur"]
    assert all(e["ph"] == "X" for e in tr.events)
    assert outer["args"] == {"step": 1}
    doc = json.load(open(tr.write(str(tmp_path / "trace.json"))))
    assert {e["name"] for e in doc["traceEvents"]} == {"inner", "outer"}


def test_span_is_noop_without_tracer():
    assert trace_mod.current() is None
    with span("phantom"):  # must not raise, must not record anywhere
        pass
    assert trace_mod.current() is None


# ---------------------------------------------------------------------------
# roofline-drift monitor
# ---------------------------------------------------------------------------

def _drift_cfg(**kw):
    base = dict(band=0.5, ema_decay=0.5, calibration_steps=2,
                skip_steps=0, rearm_frac=0.6)
    base.update(kw)
    return DriftConfig(**base)


def test_drift_exactly_one_warning_per_excursion():
    m = DriftMonitor(1.0, _drift_cfg())
    assert m.observe(1.0) is None and m.observe(1.0) is None  # calibration
    # slow excursion: only the band crossing warns, staying out is quiet
    fired = [w for w in (m.observe(4.0) for _ in range(6)) if w]
    assert len(fired) == 1
    assert fired[0]["direction"] == "slow"
    assert fired[0]["event"] == "roofline_drift"
    # back inside the re-arm band: silent, but the monitor re-arms
    assert all(m.observe(1.0) is None for _ in range(10))
    # fast excursion fires exactly once again
    fired2 = [w for w in (m.observe(0.05) for _ in range(6)) if w]
    assert len(fired2) == 1 and fired2[0]["direction"] == "fast"
    assert m.warnings == 2


def test_drift_hysteresis_no_spam_at_band_edge():
    """A ratio hovering just outside the band after the first crossing
    must not re-warn until it first re-enters the re-arm band."""
    m = DriftMonitor(1.0, _drift_cfg(ema_decay=0.1))
    m.observe(1.0), m.observe(1.0)
    warns = sum(1 for _ in range(20) if m.observe(1.6))  # hovers ~1.6
    assert warns == 1
    # dip only into the outer band (not the re-arm band): still armed off
    m.observe(1.4)
    assert m.observe(1.7) is None


def test_drift_skip_steps_discards_compile_step():
    m = DriftMonitor(0.001, DriftConfig(calibration_steps=3, skip_steps=1))
    assert m.observe(50.0) is None  # jit-compile step: not even calibration
    for _ in range(3):
        m.observe(0.01)
    assert m.scale == pytest.approx(10.0)  # poison-free baseline
    m.observe(0.01)
    assert m.ema == pytest.approx(1.0)


def test_drift_config_validation():
    for bad in (
        dict(band=0.0), dict(ema_decay=1.0), dict(ema_decay=0.0),
        dict(calibration_steps=0), dict(rearm_frac=0.0),
        dict(rearm_frac=1.0), dict(skip_steps=-1),
    ):
        with pytest.raises(ValueError):
            DriftConfig(**bad)
    with pytest.raises(ValueError):
        DriftMonitor(0.0)


def test_predict_step_bytes_scales_with_shape():
    pytest.importorskip("benchmarks.roofline")
    from repro.core.plan import ExecutionPlan
    from repro.obs.drift import predict_step_bytes, predict_step_seconds

    plan = ExecutionPlan.resolve(FOPOConfig(
        num_items=500, num_samples=32, top_k=16, epsilon=0.8,
        retriever="streaming",
    ))
    pred = predict_step_bytes(plan, 16, 8)
    assert pred is not None and pred["total_bytes"] > 0
    assert pred["total_bytes"] == (
        pred["snis_bytes"] + pred["sampler_bytes"]
        + pred["retrieval_bytes"] + pred["comms_bytes"]
    )
    assert predict_step_seconds(plan, 16, 8) > 0
    # the scaling is the signal: a bigger batch must predict more bytes
    assert predict_step_bytes(plan, 32, 8)["total_bytes"] > pred["total_bytes"]


# ---------------------------------------------------------------------------
# the declared history schema
# ---------------------------------------------------------------------------

def test_validate_history_rejects_undeclared_keys():
    h = empty_history()
    h["total_time"] = 0.0
    assert validate_history(h) is h  # declared shape passes, chains
    h["my_new_metric"] = []
    with pytest.raises(KeyError, match="my_new_metric"):
        validate_history(h)


def test_history_from_records_folds_the_stream():
    recs = [
        {"kind": "gauge", "name": "loss", "value": 1.0},
        {"kind": "timing", "name": "step_time", "value": 0.1},
        {"kind": "event", "name": "reward", "value": {"step": 4, "value": 0.5}},
        {"kind": "event", "name": "health",
         "value": {"step": 1, "verdict": 8, "checks": ["ess_collapse"]}},
        {"kind": "gauge", "name": "bus_only_metric", "value": 9.0},
        {"kind": "event", "name": "log", "value": "step 1: ..."},
    ]
    h = history_from_records(recs)
    assert h["loss"] == [1.0] and h["step_time"] == [0.1]
    assert h["reward"] == [(4, 0.5)]  # the (step, value) tuple shape
    assert h["health"][0]["verdict"] == 8
    # bus-only records exist in the stream, not in the history view
    assert "bus_only_metric" not in h and "log" not in h
    assert set(h) <= set(HISTORY_SCHEMA)


def test_verdict_record_shape():
    assert verdict_record(5, ESS_COLLAPSE) == {
        "step": 5, "verdict": ESS_COLLAPSE, "checks": ["ess_collapse"],
    }


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------

def test_trainer_history_backed_by_bus(ds):
    hist = _trainer(ds).train(6)
    validate_history(hist)
    assert len(hist["loss"]) == len(hist["step_time"]) == len(hist["ess"]) == 6
    assert all(isinstance(v, float) for v in hist["loss"])  # drained, not futures
    assert hist["total_time"] > 0
    assert hist["health"] == [] and hist["events"] == []


def test_trainer_log_lines_byte_identical_to_legacy(ds, capsys):
    """Satellite (a): the obs human sink's cadence lines reproduce the
    old raw prints exactly — reconstructed here from the history values
    with the legacy f-strings."""
    hist = _trainer(ds).train(6, log_every=2)
    out = capsys.readouterr().out.splitlines()
    expect = [
        f"step {s}: loss={hist['loss'][s - 1]:+.5f}"
        f" ess={hist['ess'][s - 1]:.1f}"
        f" rbar={hist['rbar'][s - 1]:+.4f}"
        f" max_wbar={hist['max_wbar'][s - 1]:.3f}"
        for s in (2, 4, 6)
    ]
    assert out == expect


def test_obs_is_bitwise_noop_on_trajectory(ds, tmp_path):
    bare = _trainer(ds)
    instrumented = _trainer(ds, obs=ObsConfig(
        run_dir=str(tmp_path / "run"),
        drift=DriftConfig(calibration_steps=2),
    ))
    h_bare = bare.train(6)
    h_obs = instrumented.train(6)
    assert h_bare["loss"] == h_obs["loss"]
    for a, b in zip(
        jax.tree.leaves(bare.params), jax.tree.leaves(instrumented.params)
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_obsrun_without_config_still_backs_history():
    with ObsRun(None) as run:
        run.bus.gauge("loss", 1.0, step=0)
        run.observe_step_time(0.1, 0)
        hist = run.history()
    assert hist["loss"] == [1.0]
    assert hist["step_time"] == [0.1]
    assert hist["drift"] == []  # no prediction -> monitor off


def test_monitor_bus_binding_emits_gauges():
    from repro.health import IndexHealthConfig, IndexHealthMonitor

    ring = RingSink()
    bus = MetricsBus([ring])
    monitor = IndexHealthMonitor(IndexHealthConfig(
        probe_every=1, recall_floor=0.9, cooldown=0
    ))
    monitor.bind_bus(bus)
    assert monitor.observe(0.5, 0) == "compact"
    bus.drain()
    names = [r["name"] for r in ring.records]
    assert "index_probe_recall" in names
    assert "index_overflow_delta" in names
    assert bus.total("index_ladder_escalations") == 1.0


# ---------------------------------------------------------------------------
# run artifacts + report
# ---------------------------------------------------------------------------

def test_run_artifacts_and_report(ds, tmp_path):
    """The acceptance artifact path end to end: a guarded run with a
    scripted ESS collapse leaves a JSONL stream, a Chrome trace with the
    phase spans, and a rendered report carrying loss/ESS percentiles,
    the health event and the roofline-drift series."""
    run_dir = str(tmp_path / "run")
    trainer = _trainer(
        ds,
        obs=ObsConfig(run_dir=run_dir, drift=DriftConfig(calibration_steps=2)),
        health=HealthConfig(ess_floor=1.0),
        fault=FaultPlan(ess_collapse_at=(3,), ess_value=0.5),
        steps=10,
    )
    hist = trainer.train(10, log_every=5)
    assert any("ess_collapse" in e["checks"] for e in hist["health"])
    assert len(hist["drift"]) > 0

    records = [json.loads(line)
               for line in open(os.path.join(run_dir, "metrics.jsonl"))]
    assert any(r["name"] == "loss" for r in records)
    assert any(r["name"] == "health" for r in records)

    doc = json.load(open(os.path.join(run_dir, "trace.json")))
    names = {e["name"] for e in doc["traceEvents"]}
    # host phases per step + trace-time skeleton phases (one per compile)
    assert {"dispatch", "drain", "retrieval", "sample", "surrogate"} <= names

    text = open(render_run(run_dir)).read()
    assert "| loss |" in text and "| ess |" in text  # percentile rows
    assert "ess_collapse" in text  # the health timeline
    assert "drift_ratio" in text  # the plot-ready drift series


def test_percentile_nearest_rank():
    vs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vs, 0) == 1.0
    assert percentile(vs, 100) == 4.0
    assert percentile([5.0], 99) == 5.0


def test_bench_env_block(tmp_path, monkeypatch):
    """Satellite (b): every persisted BENCH artifact carries the env
    stamp (stack versions, backend, device/host counts, git SHA)."""
    common = pytest.importorskip("benchmarks.common")
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    common.persist("unittest", [{"name": "x"}], 1.0)
    doc = json.load(open(tmp_path / "BENCH_unittest.json"))
    env = doc["env"]
    assert env["jax_version"] == jax.__version__
    assert env["backend"] and env["device_kind"]
    assert env["device_count"] >= 1 and env["host_count"] >= 1
    assert doc["rows"] == [{"name": "x"}] and doc["wall_s"] == 1.0
