"""Cell-program construction for the full assigned pool: every
(arch x shape x mesh-mode) builds abstract args + sharding trees without
touching devices. Compilation is covered by the dry-run (results/)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, all_cells, get_arch
from repro.launch.specs import build_program

CELLS = [
    (a, s)
    for a, s, cell, reason in all_cells()
    if reason is None
]
SKIPPED = [(a, s, r) for a, s, c, r in all_cells() if r is not None]


def test_pool_has_40_cells():
    assert len(CELLS) + len(SKIPPED) == 40
    assert len(SKIPPED) == 4  # long_500k on the 4 pure full-attention archs


@pytest.mark.parametrize("arch_id,shape_name", CELLS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_program_builds(arch_id, shape_name, multi_pod):
    prog = build_program(arch_id, shape_name, multi_pod=multi_pod)
    # args and in_specs must be aligned pytrees
    args_flat = jax.tree_util.tree_structure(tuple(prog.args))
    specs_flat = jax.tree_util.tree_structure(
        tuple(prog.in_specs), is_leaf=lambda x: isinstance(x, P)
    )
    assert args_flat.num_leaves == specs_flat.num_leaves, (
        args_flat.num_leaves, specs_flat.num_leaves,
    )
    assert prog.model_flops > 0
    # every sharded dim must divide by its mesh axes
    from repro.dist.sharding import AXIS_SIZES

    def check(leaf, spec):
        if not hasattr(leaf, "shape"):
            return
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in axes:
                size *= AXIS_SIZES[a]
            assert dim % size == 0, (arch_id, shape_name, leaf.shape, spec)

    jax.tree.map(
        check, tuple(prog.args), tuple(prog.in_specs),
        is_leaf=lambda x: isinstance(x, P),
    )


def test_skip_reasons_documented():
    for arch_id, shape_name, reason in SKIPPED:
        assert "full-attention" in reason
        mod = get_arch(arch_id)
        assert shape_name in mod.SKIPPED_SHAPES


def _decode_cache_specs(arch_id):
    """Flat list of the 5-dim KV-cache leaf specs of a decode cell."""
    prog = build_program(arch_id, "decode_32k")
    cache_arg, cache_spec = prog.args[2], prog.in_specs[2]
    leaves = zip(
        jax.tree.leaves(cache_arg),
        jax.tree.leaves(cache_spec, is_leaf=lambda x: isinstance(x, P)),
    )
    return [s for leaf, s in leaves if len(leaf.shape) == 5]


def test_gqa_decode_cache_never_shards_head_dim():
    # gemma2's 4 KV heads can't split the 16-way model axis; the old
    # auto rule fell back to sharding Dh, which decode's rope
    # rotate-half turns into a full cache reshard every token. The
    # decode cells must replicate BOTH head dims instead.
    for spec in _decode_cache_specs("gemma2-2b"):
        assert spec[3] is None and spec[4] is None, spec


def test_divisible_kv_decode_cache_stays_sharded():
    # olmoe's 16 KV heads divide the model axis — the override must not
    # cost it its KV shard (the cache is the decode working set).
    specs = _decode_cache_specs("olmoe-1b-7b")
    assert specs, "olmoe decode cell lost its cache leaves"
    for spec in specs:
        assert spec[3] == "model" and spec[4] is None, spec
