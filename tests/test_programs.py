"""Cell-program construction for the full assigned pool: every
(arch x shape x mesh-mode) builds abstract args + sharding trees without
touching devices. Compilation is covered by the dry-run (results/)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, all_cells, get_arch
from repro.launch.specs import build_program

CELLS = [
    (a, s)
    for a, s, cell, reason in all_cells()
    if reason is None
]
SKIPPED = [(a, s, r) for a, s, c, r in all_cells() if r is not None]


def test_pool_has_40_cells():
    assert len(CELLS) + len(SKIPPED) == 40
    assert len(SKIPPED) == 4  # long_500k on the 4 pure full-attention archs


@pytest.mark.parametrize("arch_id,shape_name", CELLS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_program_builds(arch_id, shape_name, multi_pod):
    prog = build_program(arch_id, shape_name, multi_pod=multi_pod)
    # args and in_specs must be aligned pytrees
    args_flat = jax.tree_util.tree_structure(tuple(prog.args))
    specs_flat = jax.tree_util.tree_structure(
        tuple(prog.in_specs), is_leaf=lambda x: isinstance(x, P)
    )
    assert args_flat.num_leaves == specs_flat.num_leaves, (
        args_flat.num_leaves, specs_flat.num_leaves,
    )
    assert prog.model_flops > 0
    # every sharded dim must divide by its mesh axes
    from repro.dist.sharding import AXIS_SIZES

    def check(leaf, spec):
        if not hasattr(leaf, "shape"):
            return
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in axes:
                size *= AXIS_SIZES[a]
            assert dim % size == 0, (arch_id, shape_name, leaf.shape, spec)

    jax.tree.map(
        check, tuple(prog.args), tuple(prog.in_specs),
        is_leaf=lambda x: isinstance(x, P),
    )


def test_skip_reasons_documented():
    for arch_id, shape_name, reason in SKIPPED:
        assert "full-attention" in reason
        mod = get_arch(arch_id)
        assert shape_name in mod.SKIPPED_SHAPES
