"""Quickstart: train a FOPO policy on a synthetic session-completion task
in under a minute on CPU, then serve recommendations through MIPS.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import FOPOConfig
from repro.data import SyntheticConfig, generate_sessions
from repro.mips import topk_exact
from repro.train import FOPOTrainer, TrainerConfig


def main() -> None:
    # 1. a Twitch-like (tiny) catalog: sessions split into observed X /
    #    held-out Y, SVD item embeddings, mean-embedding user contexts
    data = generate_sessions(
        SyntheticConfig(num_items=3000, num_users=1500, embed_dim=24, session_len=16)
    )
    train_ds, test_ds = data.split(0.9)

    # 2. Algorithm 1: MIPS top-K mixture proposal + SNIS covariance gradient
    trainer = FOPOTrainer(
        TrainerConfig(
            estimator="fopo",
            fopo=FOPOConfig(
                num_items=3000, num_samples=256, top_k=64, epsilon=0.8,
                retriever="streaming",
            ),
            batch_size=32,
            learning_rate=3e-3,
            num_steps=200,
        ),
        train_ds,
    )
    print(f"reward before training: {trainer.evaluate(test_ds):.4f} "
          f"(random = {8 / 3000:.4f})")
    trainer.train(200, log_every=50)
    print(f"reward after training:  {trainer.evaluate(test_ds):.4f}")

    # 3. serving: argmax over the catalog via MIPS (Eq. 5)
    h = trainer.policy.user_embedding(
        trainer.params, jnp.asarray(test_ds.contexts[:5])
    )
    top5 = topk_exact(h, trainer.beta, 5)
    print("sample recommendations (item ids):")
    for i in range(5):
        print(f"  user {i}: {top5.indices[i].tolist()}")


if __name__ == "__main__":
    main()
