"""End-to-end production-style driver: session-completion policy training
with checkpoint/restart, periodic evaluation, estimator choice, and
IVF-MIPS serving — the paper's full pipeline at configurable scale.

    PYTHONPATH=src python examples/session_completion.py \
        --items 20000 --steps 400 --estimator fopo --epsilon 0.8 \
        --ckpt /tmp/fopo_ckpt

Re-running with the same --ckpt resumes from the latest checkpoint
(simulating preemption recovery).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import FOPOConfig
from repro.data import SyntheticConfig, generate_sessions
from repro.mips import build_ivf, ivf_query
from repro.train import FOPOTrainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=20_000)
    ap.add_argument("--users", type=int, default=5_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--estimator", default="fopo",
                    choices=["fopo", "reinforce", "exact"])
    ap.add_argument("--epsilon", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=256)
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--retriever", default="streaming",
                    choices=["exact", "streaming", "ivf", "pallas"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--adaptive-eps", action="store_true")
    args = ap.parse_args()

    print(f"generating catalog P={args.items} ...")
    data = generate_sessions(
        SyntheticConfig(num_items=args.items, num_users=args.users,
                        embed_dim=args.dim, session_len=16)
    )
    train_ds, test_ds = data.split(0.9)

    kw = {}
    if args.retriever == "ivf":
        print("building IVF index (k-means over fixed beta — Assumption 1)")
        kw["index"] = build_ivf(
            jax.random.PRNGKey(0), jnp.asarray(train_ds.item_embeddings)
        )

    trainer = FOPOTrainer(
        TrainerConfig(
            estimator=args.estimator,
            fopo=FOPOConfig(
                num_items=args.items, num_samples=args.samples,
                top_k=args.top_k, epsilon=args.epsilon,
                retriever=args.retriever,
            ),
            batch_size=32,
            learning_rate=3e-3,
            num_steps=args.steps,
            adaptive_eps=args.adaptive_eps,
            checkpoint_dir=args.ckpt,
            checkpoint_every=100,
            eval_every=0,
        ),
        train_ds,
        retriever_kwargs=kw,
    )
    if args.ckpt and trainer.maybe_restore():
        print(f"resumed from checkpoint at step {trainer.step}")

    remaining = max(0, args.steps - trainer.step)
    print(f"training {remaining} steps with estimator={args.estimator} ...")
    t0 = time.perf_counter()
    hist = trainer.train(remaining, log_every=100)
    wall = time.perf_counter() - t0
    if remaining:
        print(f"  {wall / remaining * 1e3:.1f} ms/step")
    print(f"test reward: {trainer.evaluate(test_ds):.4f} "
          f"(random = {8 / args.items:.5f})")
    if args.ckpt:
        trainer.save()
        print(f"checkpointed at step {trainer.step} -> {args.ckpt}")

    # serving path: same index offline and online (the paper's key point)
    print("serving 3 requests through IVF-MIPS:")
    index = kw.get("index") or build_ivf(
        jax.random.PRNGKey(0), trainer.beta
    )
    h = trainer.policy.user_embedding(trainer.params, jnp.asarray(test_ds.contexts[:3]))
    out = ivf_query(index, h, 5, n_probe=16)
    for i in range(3):
        print(f"  user {i}: items {out.indices[i].tolist()}")


if __name__ == "__main__":
    main()
