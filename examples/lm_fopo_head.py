"""Beyond-paper integration: the FOPO estimator on an LM vocabulary head.

A reward-driven next-token objective (RL-style) over a large vocab has
the same O(V) softmax bottleneck the paper attacks for catalogs. This
demo fine-tunes a tiny LM's user-facing behaviour ("prefer tokens from a
target set") with the SNIS covariance gradient + top-K mixture proposal
over the frozen output embedding — Assumption 1, verbatim.

    PYTHONPATH=src python examples/lm_fopo_head.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lm_head import FopoLMHeadConfig, fopo_lm_head_loss
from repro.models import lm
from repro.models.configs_base import LMConfig
from repro.optim import adam


def main() -> None:
    cfg = LMConfig(
        name="tiny", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=4096, dtype="float32",
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)

    # reward: 1 if the sampled token is in the target set (e.g. a domain
    # lexicon); production would plug an offline reward model here
    target_tokens = jnp.arange(100, 200)

    def token_rewards(actions):  # [N, S'] -> [N, S']
        return (actions[..., None] == target_tokens).any(-1).astype(jnp.float32)

    head_cfg = FopoLMHeadConfig(
        vocab_size=cfg.vocab_size, num_samples=128, top_k=64, epsilon=0.5,
        retriever="exact",
    )
    out_embed = jax.lax.stop_gradient(params["unembed"])  # frozen (Assumption 1)

    opt = adam(2e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, key):
        def loss(p):
            logits, _ = lm.forward(cfg, p, toks)
            hidden = _final_hidden(cfg, p, toks)
            l, aux = fopo_lm_head_loss(
                hidden.reshape(-1, cfg.d_model), out_embed, token_rewards, key, head_cfg
            )
            return l

        l, g = jax.value_and_grad(loss)(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, l

    def _final_hidden(cfg, p, toks):
        # forward without the unembed matmul
        from repro.models.layers import rms_norm

        x = jnp.take(p["embed"], toks, axis=0)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda q: q[i], p["layers"])
            x, _ = lm._layer_fwd(cfg, x, layer, jnp.asarray(False), positions)
        return rms_norm(x, p["final_norm"], cfg.rms_eps)

    def target_mass(p):
        logits, _ = lm.forward(cfg, p, toks)
        probs = jax.nn.softmax(logits[:, -1], axis=-1)
        return float(jnp.mean(jnp.sum(probs[:, 100:200], axis=-1)))

    print(f"target-token probability before: {target_mass(params):.4f}")
    key = jax.random.PRNGKey(7)
    for i in range(100):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step(params, opt_state, sub)
    print(f"target-token probability after:  {target_mass(params):.4f}")
    print("(trained through the SNIS covariance gradient — the full-vocab "
          "softmax was never computed)")


if __name__ == "__main__":
    main()
