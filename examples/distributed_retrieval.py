"""Distributed MIPS: row-sharded catalog, per-shard streaming top-K,
global K-merge — the training-time retrieval pattern that scales FOPO to
catalogs that do not fit one device (DESIGN.md §3).

Runs on 8 simulated devices (set before jax import):

    PYTHONPATH=src python examples/distributed_retrieval.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.mips import make_sharded_topk_fn, topk_exact  # noqa: E402


def main() -> None:
    print(f"devices: {len(jax.devices())}")
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    p, l, b, k = 100_000, 64, 16, 32
    kq, ki = jax.random.split(jax.random.PRNGKey(0))
    queries = jax.random.normal(kq, (b, l))
    items = jax.random.normal(ki, (p, l))  # catalog sharded over `model`

    fn = make_sharded_topk_fn(mesh, k, "model", block_items=4096)
    with mesh:
        out = fn(queries, items)

    ref = topk_exact(queries, items, k)
    agree = (np.sort(out.indices, -1) == np.sort(np.asarray(ref.indices), -1)).mean()
    print(f"sharded top-{k} over P={p} on {mesh.devices.size} devices")
    print(f"agreement with dense oracle: {agree * 100:.2f}%")
    print(f"communication: {mesh.shape['model']} shards x B{b} x K{k} candidates "
          f"(never O(P))")
    assert agree == 1.0


if __name__ == "__main__":
    main()
